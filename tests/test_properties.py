"""Hypothesis property-based tests on system invariants.

Requires the `hypothesis` dev dependency (requirements-dev.txt); skips
cleanly (instead of erroring collection) when it is absent.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import partition_metrics, rcb_order, rcb_parts, sfc_parts
from repro.core.gather_scatter import aw_apply, gs_setup
from repro.core.pipeline import PartitionPipeline
from repro.core.rsb import _proportional_split
from repro.core.sfc import hilbert_index
from repro.mesh.graphs import build_csr, grid_graph_2d

SETTINGS = dict(max_examples=25, deadline=None)


@given(
    n=st.integers(8, 64),
    nparts=st.integers(2, 8),
    seed=st.integers(0, 1000),
)
@settings(**SETTINGS)
def test_rcb_parts_cover_and_balance(n, nparts, seed):
    rng = np.random.default_rng(seed)
    coords = rng.normal(size=(n, 3))
    parts = rcb_parts(coords, nparts)
    assert parts.shape == (n,)
    assert parts.min() >= 0 and parts.max() < nparts
    counts = np.bincount(parts, minlength=nparts)
    assert counts.max() - counts.min() <= 1


@given(n=st.integers(4, 80), seed=st.integers(0, 1000))
@settings(**SETTINGS)
def test_rcb_order_permutation(n, seed):
    coords = np.random.default_rng(seed).normal(size=(n, 3))
    order = rcb_order(coords)
    assert sorted(order.tolist()) == list(range(n))


@given(n=st.integers(8, 64), seed=st.integers(0, 500),
       nparts=st.integers(2, 6))
@settings(**SETTINGS)
def test_sfc_parts_balance(n, seed, nparts):
    coords = np.random.default_rng(seed).normal(size=(n, 3))
    parts = sfc_parts(coords, nparts)
    counts = np.bincount(parts, minlength=nparts)
    assert counts.max() - counts.min() <= 1


@given(seed=st.integers(0, 1000))
@settings(**SETTINGS)
def test_hilbert_locality_beats_random(seed):
    """Successive Hilbert-ordered points are spatially closer on average
    than randomly ordered ones (the property SFC partitioning relies on)."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(size=(128, 3))
    order = np.argsort(hilbert_index(pts, bits=8), kind="stable")
    d_h = np.linalg.norm(np.diff(pts[order], axis=0), axis=1).mean()
    d_r = np.linalg.norm(np.diff(pts, axis=0), axis=1).mean()
    assert d_h < d_r


@given(
    e=st.integers(4, 40),
    k=st.integers(2, 8),
    seed=st.integers(0, 1000),
)
@settings(**SETTINGS)
def test_gs_self_cancellation(e, k, seed):
    """L·x is invariant to adding fresh singleton ids: padding elements with
    unique gids contribute exactly zero (paper's singleton property)."""
    rng = np.random.default_rng(seed)
    gid = rng.integers(0, e, size=(e, k))
    h = gs_setup(gid)
    ones = jnp.ones((e,), jnp.float32)
    deg = aw_apply(h, ones)
    x = jnp.asarray(rng.normal(size=e), jnp.float32)
    lap = deg * x - aw_apply(h, x)
    # row sums of the implied Laplacian are zero
    assert abs(float((deg * ones - aw_apply(h, ones)).sum())) < 1e-3
    # symmetry of the quadratic form
    y = jnp.asarray(rng.normal(size=e), jnp.float32)
    ly = deg * y - aw_apply(h, y)
    assert abs(float(jnp.vdot(x, ly)) - float(jnp.vdot(y, lap))) < 1e-2 * (
        1 + abs(float(jnp.vdot(x, ly)))
    )


@given(
    n=st.integers(6, 60),
    seed=st.integers(0, 1000),
    n_left=st.integers(1, 5),
)
@settings(**SETTINGS)
def test_proportional_split_conserves(n, seed, n_left):
    rng = np.random.default_rng(seed)
    keys = rng.normal(size=n)
    w = np.ones(n)
    n_total = n_left + rng.integers(1, 5)
    lo, hi = _proportional_split(keys, w, n_left, n_total)
    assert len(lo) + len(hi) == n
    assert len(set(lo.tolist()) | set(hi.tolist())) == n
    # split ratio tracks n_left/n_total within one element
    assert abs(len(lo) - n * n_left / n_total) <= 1


@given(
    n=st.integers(6, 40),
    m=st.integers(5, 80),
    nparts=st.integers(2, 5),
    seed=st.integers(0, 1000),
)
@settings(**SETTINGS)
def test_metrics_conservation(n, m, nparts, seed):
    """Edge cut + internal weight = total weight; volumes symmetric."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    g = build_csr(src, dst, n)
    if g.nnz == 0:
        return
    parts = rng.integers(0, nparts, n)
    pm = partition_metrics(g, parts, nparts)
    total_w = g.weights.sum() / 2
    internal = total_w - pm.edge_cut
    assert 0 <= pm.edge_cut <= total_w + 1e-9
    assert internal >= -1e-9
    # total outgoing volume counts each cut edge twice (once per side)
    assert abs(pm.total_volume - 2 * pm.edge_cut) < 1e-9


@given(
    nx=st.integers(4, 9),
    ny=st.integers(4, 9),
    nparts=st.integers(2, 6),
    seed=st.integers(0, 50),
)
@settings(max_examples=15, deadline=None)
def test_multilevel_prolonged_labels_repair_to_connected(nx, ny, nparts, seed):
    """V-cycle labels (prolonged by aggregate copy through an arbitrary
    ladder) stay repairable: the closing repair stage always reaches zero
    disconnected parts with every part label populated."""
    g = grid_graph_2d(nx, ny)
    ctx = PartitionPipeline(
        pre="none", bisect="multilevel", post=("repair",),
        bisect_kw=dict(seed=seed, coarse_factor=4)).run(g, nparts)
    pm = partition_metrics(g, ctx.parts, nparts)
    assert pm.disconnected_parts == 0
    assert set(np.unique(ctx.parts)) == set(range(nparts))
