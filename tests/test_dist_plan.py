"""Single-process unit tests for `plan_halo_sharding` invariants.

These run with ONE device (no shard_map): they check the host-side NumPy
planning that the distributed paths (test_distributed.py) build on —
edge coverage, halo = boundary-node count, padding masks, and the
scatter/gather round trip.
"""

import numpy as np
import pytest

from repro.dist.partition_aware import (
    HaloPlan,
    gather_features,
    plan_halo_sharding,
    scatter_features,
)
from repro.mesh.graphs import grid_graph_2d, stencil_graph_3d


@pytest.fixture(scope="module")
def cases():
    rng = np.random.default_rng(0)
    out = []
    g = grid_graph_2d(12, 12)
    out.append((g, rng.integers(0, 4, g.n), 4))          # unbalanced random
    out.append((g, np.arange(g.n) % 6, 6))               # strided
    g3 = stencil_graph_3d(4, 4, 4)
    out.append((g3, rng.integers(0, 5, g3.n), 5))        # 5 parts, 26-stencil
    return out


def test_every_edge_covered_exactly_once(cases):
    for g, parts, nparts in cases:
        plan = plan_halo_sharding(g, parts, nparts)
        # number of real (unmasked) edge slots across shards == directed nnz
        assert int(plan.edge_mask.sum()) == g.nnz
        # and each real slot reproduces a distinct CSR entry: rebuild the
        # dense adjacency from the plan and compare with the oracle
        A = np.zeros((g.n, g.n))
        A[g.rows, g.indices] = g.weights
        B = np.zeros_like(A)
        node_of = np.full((nparts, plan.n_local), -1, np.int64)
        node_of[plan.shard_of, plan.slot_of] = np.arange(g.n)
        # combined index -> global node id, per shard
        exp_node = np.full((nparts, max(plan.halo, 1)), -1, np.int64)
        for s in range(nparts):
            for j in range(plan.halo):
                if plan.export_mask[s, j]:
                    exp_node[s, j] = node_of[s, plan.export_idx[s, j]]
        for s in range(nparts):
            for k in range(plan.max_edges):
                if not plan.edge_mask[s, k]:
                    continue
                dst = node_of[s, plan.edge_dst[s, k]]
                src_c = plan.edge_src[s, k]
                if src_c < plan.n_local:
                    src = node_of[s, src_c]
                else:
                    r, j = divmod(src_c - plan.n_local, plan.halo)
                    src = exp_node[r, j]
                assert src >= 0 and dst >= 0
                assert B[dst, src] == 0, "edge covered twice"
                B[dst, src] = plan.edge_weight[s, k]
        np.testing.assert_allclose(B, A, atol=1e-6)


def test_halo_equals_max_boundary_count(cases):
    for g, parts, nparts in cases:
        plan = plan_halo_sharding(g, parts, nparts)
        cross = parts[g.rows] != parts[g.indices]
        boundary = np.unique(g.indices[cross])            # nodes needed remotely
        per_shard = np.bincount(parts[boundary], minlength=nparts)
        assert plan.halo == int(per_shard.max())
        # per-shard real export rows == that shard's boundary count
        np.testing.assert_array_equal(
            plan.export_mask.sum(1).astype(np.int64), per_shard
        )


def test_padding_rows_fully_masked(cases):
    for g, parts, nparts in cases:
        plan = plan_halo_sharding(g, parts, nparts)
        counts = np.bincount(parts, minlength=nparts)
        np.testing.assert_array_equal(plan.block_sizes, counts)
        # padded node slots receive nothing from scatter
        x = np.ones(g.n)
        blocks = scatter_features(plan, x)
        for s in range(nparts):
            assert blocks[s, : counts[s]].all()
            assert not blocks[s, counts[s]:].any()
        # masked edge/export slots carry zero weight/mask
        assert (plan.edge_weight[plan.edge_mask == 0] == 0).all()
        assert (plan.export_idx[plan.export_mask == 0] == 0).all()


def test_scatter_gather_round_trip(cases):
    rng = np.random.default_rng(3)
    for g, parts, nparts in cases:
        plan = plan_halo_sharding(g, parts, nparts)
        for shape in ((g.n,), (g.n, 7)):
            x = rng.normal(size=shape)
            np.testing.assert_array_equal(
                gather_features(plan, scatter_features(plan, x)), x
            )


def test_collective_words_tracks_cut():
    """Fewer cut edges ⇒ smaller halo ⇒ fewer all_gather words."""
    g = grid_graph_2d(16, 16)
    strips = (np.arange(g.n) // (g.n // 4)).clip(max=3)   # contiguous strips
    scatter = np.arange(g.n) % 4                          # worst case
    p_good = plan_halo_sharding(g, strips, 4)
    p_bad = plan_halo_sharding(g, scatter, 4)
    assert isinstance(p_good, HaloPlan)
    assert p_good.halo < p_bad.halo
    assert (p_good.collective_words_per_feature
            < p_bad.collective_words_per_feature)


def test_pad_to_and_stats():
    """benchmarks/hillclimb.py's contract: pad_to=8 lane alignment + a
    JSON-able stats() record."""
    import json

    g = grid_graph_2d(11, 11)                       # odd sizes everywhere
    parts = np.random.default_rng(7).integers(0, 3, g.n)
    plan = plan_halo_sharding(g, parts, 3, pad_to=8)
    assert plan.n_local % 8 == 0
    assert plan.halo % 8 == 0
    assert plan.max_edges % 8 == 0
    # padding stays fully masked and the plan still covers every edge
    assert int(plan.edge_mask.sum()) == g.nnz
    unpadded = plan_halo_sharding(g, parts, 3)
    assert unpadded.halo <= plan.halo < unpadded.halo + 8
    s = plan.stats()
    json.dumps(s)                                   # JSON-able
    assert s["halo"] == plan.halo and 0 < s["edge_fill"] <= 1
    with pytest.raises(ValueError):
        plan_halo_sharding(g, parts, 3, pad_to=0)


def test_plan_validates_inputs():
    g = grid_graph_2d(4, 4)
    with pytest.raises(ValueError):
        plan_halo_sharding(g, np.zeros(5, np.int64), 2)
    with pytest.raises(ValueError):
        plan_halo_sharding(g, np.full(g.n, 3, np.int64), 2)
    plan = plan_halo_sharding(g, np.zeros(g.n, np.int64), 1)
    with pytest.raises(ValueError):
        scatter_features(plan, np.zeros((g.n + 1, 2)))
    with pytest.raises(ValueError):
        gather_features(plan, np.zeros((2, plan.n_local)))
