"""Multilevel acceleration of the batched RSB engine: cascadic
coarse-to-fine warm starts, the packed BatchedAMG V-cycle, and their
behaviour on weighted / disconnected subproblems."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    amg_setup_batched,
    ell_laplacian,
    fiedler_from_graph,
    fiedler_from_graph_batched,
    fiedler_from_mesh_batched,
    fiedler_oracle_np,
    multilevel_warm_start,
    partition_metrics,
    rsb_partition_graph,
    rsb_partition_mesh,
)
from repro.core.fiedler import next_pow2
from repro.mesh import box_mesh, dual_graph, grid_graph_2d, pebble_mesh
from repro.mesh.graphs import build_csr


@pytest.fixture(scope="module")
def pebble():
    m = pebble_mesh(10, 10, 10, n_pebbles=4, warp=0.1, seed=2)
    return m, dual_graph(m)


# ---------------------------------------------------------------------------
# Coarse-to-fine warm starts
# ---------------------------------------------------------------------------

def test_multilevel_warm_start_shapes_and_cutoff():
    g = grid_graph_2d(20, 20)
    warm, levels = multilevel_warm_start(g)
    assert warm is not None and warm.shape == (g.n,)
    assert np.isfinite(warm).all() and levels >= 1
    # at/below the cutoff there is nothing to coarsen
    small = grid_graph_2d(8, 8)
    warm, levels = multilevel_warm_start(small)
    assert warm is None and levels == 0


def test_multilevel_reduces_restarts():
    g = grid_graph_2d(24, 28)
    cold = fiedler_from_graph(g, tol=1e-4, multilevel=False)
    warm = fiedler_from_graph(g, tol=1e-4, multilevel=True)
    lam, _ = fiedler_oracle_np(g)
    assert warm.iterations <= cold.iterations
    assert warm.levels >= 1 and cold.levels == 0
    assert warm.eigenvalue == pytest.approx(lam, rel=2e-2, abs=1e-4)


def test_multilevel_batch_of_one_matches_unbatched():
    g = grid_graph_2d(20, 20)
    r1 = fiedler_from_graph(g, tol=1e-4)
    rb = fiedler_from_graph_batched([g], tol=1e-4)[0]
    assert rb.iterations == r1.iterations
    assert rb.levels == r1.levels
    cos = abs(np.dot(r1.vector, rb.vector)) / (
        np.linalg.norm(r1.vector) * np.linalg.norm(rb.vector)
    )
    assert cos > 0.999


def test_coarse_to_fine_bisection_weighted_graph(pebble):
    """Coarse-to-fine warm starts must yield a valid bisection on a
    weighted dual graph (the engine default path): balanced at every
    power-of-two level, cut within 5% of the non-multilevel engine."""
    m, g = pebble
    p_ml, rep_ml = rsb_partition_graph(g, 8, coords=m.coords, tol=1e-3,
                                       multilevel=True)
    p_cold, rep_cold = rsb_partition_graph(g, 8, coords=m.coords, tol=1e-3,
                                           multilevel=False)
    for parts in (p_ml, p_cold):
        counts = np.bincount(parts, minlength=8)
        assert counts.max() - counts.min() <= 1
    c_ml = partition_metrics(g, p_ml, 8).edge_cut
    c_cold = partition_metrics(g, p_cold, 8).edge_cut
    assert c_ml <= 1.05 * c_cold
    assert rep_ml.multilevel and rep_ml.precond_levels >= 1
    # the multilevel schedule must also do less iterative work
    assert rep_ml.total_iterations <= rep_cold.total_iterations


def test_multilevel_mesh_path_records_levels():
    m = box_mesh(8, 8, 8)
    _, rep = rsb_partition_mesh(m, 8, tol=1e-3, engine="batched")
    assert rep.multilevel
    assert rep.precond_levels >= 1
    solved = [r for r in rep.records if r.method != "dense"]
    assert all(r.levels >= 1 for r in solved)


# ---------------------------------------------------------------------------
# Batched AMG V-cycle
# ---------------------------------------------------------------------------

def test_batched_amg_vcycle_contracts_per_problem():
    """Each problem's residual contracts independently (no cross-problem
    coupling through the packed hierarchy)."""
    graphs = [grid_graph_2d(20, 20), grid_graph_2d(16, 25)]
    n_pad = next_pow2(max(g.n for g in graphs))
    pre = amg_setup_batched(graphs, n_pad, 2)
    rng = np.random.default_rng(0)
    R = np.zeros((2, n_pad), dtype=np.float32)
    for b, g in enumerate(graphs):
        r = rng.normal(size=g.n)
        R[b, : g.n] = r - r.mean()
    U = np.asarray(pre(jnp.asarray(R)))
    assert np.isfinite(U).all()
    for b, g in enumerate(graphs):
        op = ell_laplacian(g)
        res = R[b, : g.n] - np.asarray(op.apply(jnp.asarray(U[b, : g.n])))
        assert np.linalg.norm(res) < 0.9 * np.linalg.norm(R[b, : g.n])
        # padding rows of the cycle output never leak into real rows
        assert U.shape == (2, n_pad)


def test_batched_inverse_amg_batch_of_one_parity():
    """AMG-preconditioned batched inverse iteration vs the unbatched
    (host-AMG) reference: same eigenpair on a batch of one.  (Non-square
    grid: a square one has a degenerate λ₂ eigenspace, paper §9, and
    comparing against one specific eigenvector would be meaningless.)"""
    g = grid_graph_2d(20, 26)
    lam, y = fiedler_oracle_np(g)
    rb = fiedler_from_graph_batched([g], method="inverse", precond="amg",
                                    tol=1e-4)[0]
    ru = fiedler_from_graph(g, method="inverse", tol=1e-4)
    assert rb.method == "inverse" and rb.levels >= 1
    for r in (rb, ru):
        assert r.eigenvalue == pytest.approx(lam, rel=2e-2, abs=1e-4)
    cos = abs(np.dot(rb.vector, y)) / (np.linalg.norm(rb.vector) * np.linalg.norm(y))
    assert cos > 0.99


def test_batched_inverse_amg_multi_problem():
    graphs = [grid_graph_2d(20, 20), grid_graph_2d(16, 25),
              grid_graph_2d(24, 14)]
    results = fiedler_from_graph_batched(graphs, method="inverse",
                                         precond="amg", tol=1e-4)
    for g, r in zip(graphs, results):
        lam, _ = fiedler_oracle_np(g)
        assert r.eigenvalue == pytest.approx(lam, rel=2e-2, abs=1e-4)


def test_batched_inverse_amg_mesh_path():
    m = box_mesh(8, 8, 4)
    g = dual_graph(m)
    lam, _ = fiedler_oracle_np(g)
    r = fiedler_from_mesh_batched([m.vert_gid], method="inverse",
                                  precond="amg", tol=1e-3)[0]
    assert r.eigenvalue == pytest.approx(lam, rel=5e-2, abs=1e-3)
    assert r.levels >= 1


def test_amg_precond_bad_name_raises():
    g = grid_graph_2d(20, 20)
    with pytest.raises(ValueError):
        fiedler_from_graph_batched([g], method="inverse", precond="nope")


# ---------------------------------------------------------------------------
# Disconnection mid-recursion
# ---------------------------------------------------------------------------

def _two_component_graph():
    """Two disjoint 4-neighbor grids in one node set — the shape of an RSB
    child subgraph that disconnected when its parent was split."""
    a = grid_graph_2d(16, 16)
    b = grid_graph_2d(12, 20)
    n = a.n + b.n
    src = np.concatenate([a.rows, b.rows + a.n])
    dst = np.concatenate([a.indices, b.indices + a.n])
    w = np.concatenate([a.weights, b.weights])
    return build_csr(src, dst, n, weights=w, symmetrize=False), a.n


def test_vcycle_on_disconnected_subgraph():
    """The packed V-cycle (singular coarse pinv per component) must stay
    finite on a disconnected subproblem, and the Fiedler solve must
    recover λ₂ ≈ 0 with a sign split separating the components."""
    g, n_a = _two_component_graph()
    r = fiedler_from_graph_batched([g], method="inverse", precond="amg",
                                   tol=1e-3)[0]
    assert np.isfinite(r.vector).all()
    assert abs(r.eigenvalue) < 1e-3
    # the λ₂ = 0 eigenspace is spanned by component indicators: the solve
    # must place the two components on opposite sides
    sa = np.sign(np.median(r.vector[:n_a]))
    sb = np.sign(np.median(r.vector[n_a:]))
    assert sa != 0 and sb != 0 and sa != sb


def test_rsb_on_disconnecting_graph():
    """End-to-end: a graph that disconnects mid-recursion still partitions
    balanced under the multilevel default engine."""
    g, _ = _two_component_graph()
    for precond in ("jacobi", "amg"):
        parts, _ = rsb_partition_graph(g, 4, method="inverse",
                                       precond=precond, tol=1e-3)
        counts = np.bincount(parts, minlength=4)
        assert counts.max() - counts.min() <= 1
