"""Fiedler solvers: Lanczos, inverse iteration (flexcg + AMG) vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    amg_setup,
    dense_laplacian_np,
    ell_laplacian,
    fiedler_from_graph,
    fiedler_from_mesh,
    fiedler_oracle_np,
    flexcg,
)
from repro.mesh import box_mesh, dual_graph, grid_graph_2d, grid_graph_3d


def _check_eigpair(graph, res, tol=2e-2):
    lam, _ = fiedler_oracle_np(graph)
    assert res.eigenvalue == pytest.approx(lam, rel=tol, abs=1e-4)


def test_lanczos_grid(grid16):
    res = fiedler_from_graph(grid16, method="lanczos", tol=1e-4)
    _check_eigpair(grid16, res)


def test_inverse_grid(grid16):
    res = fiedler_from_graph(grid16, method="inverse", tol=1e-4)
    _check_eigpair(grid16, res)


def test_lanczos_3d():
    g = grid_graph_3d(8, 8, 8)
    res = fiedler_from_graph(g, method="lanczos", tol=1e-3)
    _check_eigpair(g, res, tol=5e-2)


def test_mesh_gs_lanczos():
    """Matrix-free gather-scatter Lanczos on a box dual graph."""
    m = box_mesh(6, 6, 6)
    g = dual_graph(m)
    res = fiedler_from_mesh(m.vert_gid, method="lanczos", tol=1e-3)
    lam, _ = fiedler_oracle_np(g)
    assert res.eigenvalue == pytest.approx(lam, rel=5e-2, abs=1e-3)


def test_fiedler_vector_orthogonal_to_ones(grid16):
    res = fiedler_from_graph(grid16, method="lanczos", tol=1e-4)
    assert abs(res.vector.sum()) < 1e-2 * np.linalg.norm(res.vector) * np.sqrt(grid16.n)


def test_flexcg_identity_precond_solves(grid16):
    """flexcg solves L x = b (b ⊥ 1) without preconditioning."""
    op = ell_laplacian(grid16)
    rng = np.random.default_rng(0)
    b = rng.normal(size=grid16.n).astype(np.float32)
    b -= b.mean()
    res = jax.jit(lambda bb: flexcg(op.apply, bb, tol=1e-6, maxiter=2000))(
        jnp.asarray(b)
    )
    x = np.asarray(res.x)
    np.testing.assert_allclose(
        np.asarray(op.apply(jnp.asarray(x))), b, atol=5e-3
    )


def test_flexcg_single_iteration_on_eigvector(grid16):
    """Paper §7 (claim C5): when b IS an eigenvector, the L-Krylov space is
    invariant and flexcg (unpreconditioned first direction) converges in
    one iteration."""
    lam, y2 = fiedler_oracle_np(grid16)
    op = ell_laplacian(grid16)
    b = jnp.asarray(y2.astype(np.float32))
    pre = amg_setup(grid16)
    res = flexcg(op.apply, b, precond=pre, tol=1e-4, maxiter=100)
    assert int(res.iters) <= 2  # 1 + possible roundoff iteration


def test_amg_accelerates_cg(grid16):
    """AMG-preconditioned flexcg needs fewer iterations than plain CG."""
    op = ell_laplacian(grid16)
    rng = np.random.default_rng(1)
    b = rng.normal(size=grid16.n).astype(np.float32)
    b -= b.mean()
    b = jnp.asarray(b)
    plain = flexcg(op.apply, b, tol=1e-6, maxiter=2000)
    pre = amg_setup(grid16)
    amg = flexcg(op.apply, b, precond=pre, tol=1e-6, maxiter=2000)
    assert int(amg.iters) < int(plain.iters)
    assert float(amg.resnorm) <= 1e-5 * max(float(jnp.linalg.norm(b)), 1.0)


def test_amg_vcycle_reduces_residual(grid16):
    """One V-cycle contracts the error of L u = r."""
    pre = amg_setup(grid16)
    op = ell_laplacian(grid16)
    rng = np.random.default_rng(2)
    r = rng.normal(size=grid16.n).astype(np.float32)
    r -= r.mean()
    u = pre(jnp.asarray(r))
    res = np.asarray(r - np.asarray(op.apply(u)))
    assert np.linalg.norm(res) < 0.9 * np.linalg.norm(r)


def test_galerkin_coarsening_preserves_laplacian(grid16):
    """Coarse operators keep zero row sums + nonpositive off-diagonals."""
    from repro.core import coarsen_graph

    agg = np.arange(grid16.n) // 2
    gc = coarsen_graph(grid16, agg, (grid16.n + 1) // 2)
    Lc = dense_laplacian_np(gc)
    np.testing.assert_allclose(Lc.sum(1), 0, atol=1e-9)
    off = Lc - np.diag(np.diag(Lc))
    assert (off <= 1e-12).all()


def test_degenerate_fiedler_pair_sweep():
    """Paper §9 (implemented): on a checkerboard-degenerate N×N grid,
    deflated Lanczos recovers BOTH members of the λ₂ eigenspace and the
    θ-sweep finds a near-optimal straight cut where a single arbitrary
    eigenvector may give a diagonal (≈2N) cut."""
    from repro.core import best_cut_in_pair, fiedler_pair_from_graph
    from repro.mesh import grid_graph_2d

    N = 20
    g = grid_graph_2d(N, N)
    y1, y2, l2, l3 = fiedler_pair_from_graph(g, seed=3)
    assert abs(l2 - l3) < 1e-3 * max(l2, 1e-9)        # degenerate pair
    assert abs(float(y1 @ y2)) < 1e-5                 # orthogonal
    v, theta, cut = best_cut_in_pair(g, y1, y2)
    assert cut <= N + 2                               # near-optimal straight cut
