"""Serve a small LM with batched requests (prefill + KV-cache decode).

    PYTHONPATH=src python examples/serve_lm.py --batch 4 --steps 32
"""


from repro.launch.serve import main

if __name__ == "__main__":
    main()
