"""The paper's technique as the framework's communication optimizer:
partition a GNN's graph with RSB, shard message passing with shard_map,
and measure the collective volume vs naive partitions.

Sets up 8 host devices — run as its own process:
    PYTHONPATH=src python examples/partition_aware_gnn.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np
from jax.sharding import AxisType

from repro.core import PartitionPipeline, partition_metrics
from repro.core.rcb import rcb_parts
from repro.dist.partition_aware import adjacency_matvec_distributed, plan_halo_sharding
from repro.mesh.graphs import grid_graph_2d

n_shards = 8
g = grid_graph_2d(32, 32)
coords = np.stack(np.meshgrid(np.arange(32), np.arange(32), indexing="ij"),
                  -1).reshape(-1, 2).astype(float)
coords = np.concatenate([coords, np.zeros((g.n, 1))], 1)

# The full parRSB pipeline: per-level RCB reorder → batched spectral
# bisection → component repair + FM boundary smoothing.  The context it
# returns (labels + report with post-stage metrics) feeds the halo planner
# directly.
ctx = PartitionPipeline(bisect_kw=dict(tol=1e-4)).run(
    g, n_shards, coords=coords)
post = ctx.report.post
print(f"graph: {g.n} nodes, {g.nnz // 2} edges, {n_shards} shards")
print(f"rsb post stage: {post.fragments_repaired} fragments repaired, "
      f"{post.moves_applied} boundary moves, "
      f"cut {post.cut_before:.0f} -> {post.cut_after:.0f}")
print(f"{'partitioner':<12}{'edge cut':>9}{'halo':>6}{'gather words/col':>18}")
plans = {}
for name, parts in (
    ("random", np.random.default_rng(0).permutation(np.arange(g.n) % n_shards)),
    ("rcb", rcb_parts(coords, n_shards)),
    ("rsb", ctx),   # pipeline context: plan_halo_sharding takes it whole
):
    plan = plan_halo_sharding(g, parts, n_shards)
    pm = partition_metrics(g, parts if isinstance(parts, np.ndarray)
                           else ctx.parts, n_shards)
    plans[name] = plan
    print(f"{name:<12}{pm.edge_cut:>9.0f}{plan.halo:>6}"
          f"{plan.collective_words_per_feature:>18}")

# run one REAL distributed message-passing sweep under each plan
mesh = jax.make_mesh((n_shards,), ("shards",), axis_types=(AxisType.Auto,))
x = np.random.default_rng(1).normal(size=g.n)
A = np.zeros((g.n, g.n)); A[g.rows, g.indices] = g.weights
with jax.set_mesh(mesh):
    for name, plan in plans.items():
        y = adjacency_matvec_distributed(plan, mesh, x)
        err = np.abs(y - A @ x).max()
        print(f"distributed A·x under {name:<7} plan: max err {err:.2e}")
print("\nRSB's min-cut objective == minimal all_gather volume: the paper's "
      "partitioner is the framework's communication optimizer.")
