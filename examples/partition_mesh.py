"""The NekRS workflow: mesh → partition → element redistribution, with all
partitioners compared (RSB / RCB / RIB / SFC / random).

    PYTHONPATH=src python examples/partition_mesh.py
"""

import numpy as np

from repro import obs
from repro.core import (PartitionPipeline, partition, partition_metrics,
                        run_post_stages)
from repro.dist.partition_aware import plan_halo_sharding, scatter_features
from repro.mesh import dual_graph, pebble_mesh

mesh = pebble_mesh(12, 12, 12, n_pebbles=5, warp=0.15, seed=1)
graph = dual_graph(mesh)
nparts = 16
print(f"pebble-bed-like mesh: {mesh.nelems} elements "
      f"({(mesh.weights > 1).sum()} 'flow' elements at 2x weight)")
print(f"{'method':<12}{'cut':>8}{'volume':>9}{'maxnbr':>7}{'halo':>6}"
      f"{'w-imb':>7}{'disc':>6}")
# ONE pipeline run yields all three rsb rows: "rsb" is the full pipeline
# (repair + greedy FM refinement on by default), "rsb_raw" its parts_raw —
# the same bisection before the post stage — and "rsb_kway" the same
# bisection refined by the hill-climbing k-way FM chain instead, so the
# gaps between the rows are exactly what each post chain recovers.
ctx = PartitionPipeline().run(mesh, nparts)
parts_kway, _, _ = run_post_stages(graph, ctx.parts_raw, nparts,
                                   ("repair", "kway"), weights=ctx.weights)
rows = [("rsb", ctx.parts), ("rsb_kway", parts_kway),
        ("rsb_raw", ctx.parts_raw)]
rows += [(name, partition(mesh, nparts, partitioner=name))
         for name in ("rcb", "rib", "sfc", "random")]
for name, parts in rows:
    pm = partition_metrics(graph, parts, nparts, weights=mesh.weights)
    halo = plan_halo_sharding(graph, parts, nparts).halo
    print(f"{name:<12}{pm.edge_cut:>8.0f}{pm.total_volume:>9.0f}"
          f"{pm.max_neighbors:>7}{halo:>6}{pm.weighted_imbalance:>7.3f}"
          f"{pm.disconnected_parts:>6}")

# element redistribution: permute element data into per-rank blocks — this
# is the 'apply the partition' step a solver performs before timestepping
plan = plan_halo_sharding(graph, ctx)
blocks = scatter_features(plan, mesh.coords)
print(f"\nredistributed coords into {blocks.shape} per-rank blocks "
      f"(halo capacity {plan.halo} elements/rank)")

# where the wall clock went: the pipeline run's span tree (name, ms, % of
# wall, counters) — obs.render of the trace PartitionPipeline recorded
print("\nrsb pipeline trace (% of wall):")
print(obs.render(ctx.trace))
