"""The NekRS workflow: mesh → partition → element redistribution, with all
partitioners compared (RSB / RCB / RIB / SFC / random).

    PYTHONPATH=src python examples/partition_mesh.py \
        [--dims NX NY NZ] [--pebbles K] [--nparts P] [--seed S] \
        [--devices N]

Bad sizes go through the guard's validation front door and come back as a
typed diagnostic (exit 2), not a traceback.

``--devices N`` (N > 1) adds the device-resident sharded refinement row
(``rsb_sharded``, dist/refine_sharded) and prints its span tree — one
``sweep:k`` span per collective round with the halo_words/halo_bytes
exchange cost on each.  The default (1 device) keeps the host refinement
path and skips the demo.
"""

import argparse
import os
import sys

# The forced host-device count must reach XLA before jax is (transitively)
# imported below, so peek at --devices ahead of the real argparse run.
_pre = argparse.ArgumentParser(add_help=False)
_pre.add_argument("--devices", default=1)
try:
    _ndev = int(_pre.parse_known_args()[0].devices)
except (ValueError, TypeError):
    _ndev = 1
if _ndev > 1 and "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_ndev}").strip()


from repro import obs
from repro.core import PartitionPipeline, partition, partition_metrics, run_post_stages
from repro.dist.partition_aware import plan_halo_sharding, scatter_features
from repro.guard import GuardError, check_positive_int, validate_mesh, validate_nparts
from repro.mesh import dual_graph, pebble_mesh


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dims", nargs=3, default=[12, 12, 12],
                    metavar=("NX", "NY", "NZ"))
    ap.add_argument("--pebbles", default=5)
    ap.add_argument("--nparts", default=16)
    ap.add_argument("--seed", default=1)
    ap.add_argument("--devices", default=1,
                    help="emulated device count for the sharded-refinement "
                         "demo (default 1 = host path only)")
    args = ap.parse_args(argv)

    try:
        nx, ny, nz = (check_positive_int(name, v) for name, v in
                      zip(("nx", "ny", "nz"), args.dims))
        n_pebbles = check_positive_int("pebbles", args.pebbles, minimum=0)
        seed = check_positive_int("seed", args.seed, minimum=0)
        devices = check_positive_int("devices", args.devices)
        mesh = pebble_mesh(nx, ny, nz, n_pebbles=n_pebbles, warp=0.15,
                           seed=seed)
        nparts = check_positive_int("nparts", args.nparts)
        validate_nparts(nparts, mesh.nelems)
        mesh = validate_mesh(mesh, nparts=nparts)
    except GuardError as err:
        print(err.diagnostic(), file=sys.stderr)
        return 2

    graph = dual_graph(mesh)
    print(f"pebble-bed-like mesh: {mesh.nelems} elements "
          f"({(mesh.weights > 1).sum()} 'flow' elements at 2x weight)")
    print(f"{'method':<12}{'cut':>8}{'volume':>9}{'maxnbr':>7}{'halo':>6}"
          f"{'w-imb':>7}{'disc':>6}")
    # ONE pipeline run yields all three rsb rows: "rsb" is the full pipeline
    # (repair + greedy FM refinement on by default), "rsb_raw" its parts_raw —
    # the same bisection before the post stage — and "rsb_kway" the same
    # bisection refined by the hill-climbing k-way FM chain instead, so the
    # gaps between the rows are exactly what each post chain recovers.
    ctx = PartitionPipeline().run(mesh, nparts)
    parts_kway, _, _ = run_post_stages(graph, ctx.parts_raw, nparts,
                                       ("repair", "kway"),
                                       weights=ctx.weights)
    rows = [("rsb", ctx.parts), ("rsb_kway", parts_kway),
            ("rsb_raw", ctx.parts_raw)]
    sharded_root = None
    if devices > 1:
        # Device-resident sweeps over the same bisection labels: shards
        # exchange ONE fused boundary-label all_gather per sweep; the
        # span tree below prices each round (halo_words/halo_bytes).
        with obs.trace("rsb_sharded") as sharded_root:
            parts_sharded, _, _ = run_post_stages(
                graph, ctx.parts_raw, nparts,
                ("repair", "refine-sharded"), weights=ctx.weights,
                post_kw=dict(sweeps=8))
        rows.insert(1, ("rsb_sharded", parts_sharded))
    rows += [(name, partition(mesh, nparts, partitioner=name))
             for name in ("rcb", "rib", "sfc", "random")]
    for name, parts in rows:
        pm = partition_metrics(graph, parts, nparts, weights=mesh.weights)
        halo = plan_halo_sharding(graph, parts, nparts).halo
        print(f"{name:<12}{pm.edge_cut:>8.0f}{pm.total_volume:>9.0f}"
              f"{pm.max_neighbors:>7}{halo:>6}{pm.weighted_imbalance:>7.3f}"
              f"{pm.disconnected_parts:>6}")

    # element redistribution: permute element data into per-rank blocks —
    # this is the 'apply the partition' step a solver performs before
    # timestepping
    plan = plan_halo_sharding(graph, ctx)
    blocks = scatter_features(plan, mesh.coords)
    print(f"\nredistributed coords into {blocks.shape} per-rank blocks "
          f"(halo capacity {plan.halo} elements/rank)")

    # where the wall clock went: the pipeline run's span tree (name, ms, %
    # of wall, counters) — obs.render of the trace the pipeline recorded
    print("\nrsb pipeline trace (% of wall):")
    print(obs.render(ctx.trace))
    if sharded_root is not None:
        import jax

        print(f"\nsharded refinement trace ({len(jax.devices())} devices, "
              "per-sweep exchange cost):")
        print(obs.render(sharded_root))
    return 0


if __name__ == "__main__":
    sys.exit(main())
