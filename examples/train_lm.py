"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py --steps 300
(CPU: ~2-4 s/step at the default batch. Use --steps 10 for a smoke run.)
"""

import argparse

import jax
import jax.numpy as jnp

from repro.data.pipeline import Prefetcher
from repro.data.synthetic import token_batches
from repro.models.common import count_params
from repro.models.transformer import LMConfig, init_params, loss_fn
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import fit

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--seq", type=int, default=64)
ap.add_argument("--ckpt-dir", default="runs/train_lm_100m")
args = ap.parse_args()

# ~100M params: 10 layers, d=640, llama-style (GQA + SwiGLU + RoPE)
cfg = LMConfig(name="lm-100m", n_layers=10, d_model=640, n_heads=10,
               n_kv_heads=2, d_head=64, d_ff=1792, vocab=32000,
               dtype=jnp.float32)
params = init_params(cfg, jax.random.PRNGKey(0))
print(f"model: {cfg.name}  params={count_params(params):,}")

data = Prefetcher(token_batches(args.batch, args.seq, cfg.vocab, seed=0))
res = fit(
    lambda p, b: loss_fn(cfg, p, b), params, data,
    steps=args.steps, opt_cfg=AdamWConfig(lr=3e-4, weight_decay=0.01),
    ckpt_dir=args.ckpt_dir, ckpt_every=100,
    log_every=max(args.steps // 30, 1),
)
print(f"final loss: {res.losses[-1][1]:.4f} (started {res.losses[0][1]:.4f})")
