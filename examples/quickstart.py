"""Quickstart: partition a mesh with parRSB and inspect quality.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import comm_time_model, partition_metrics, rsb_partition_mesh
from repro.mesh import box_mesh, dual_graph

# 1. Build a mesh (any (E, 8) global-vertex-id table works — this is the
#    same input parRSB takes from Nek5000/NekRS).
mesh = box_mesh(12, 12, 8)
print(f"mesh: {mesh.nelems} hex elements, {mesh.n_vert} vertices")

# 2. Recursive Spectral Bisection on the dual graph (matrix-free
#    gather-scatter Laplacian, Lanczos Fiedler solver, RCB pre-pass).
parts, report = rsb_partition_mesh(mesh, nparts=16, method="lanczos",
                                   pre="rcb", tol=1e-3)
print(f"partitioned into 16 parts in {report.seconds:.1f}s "
      f"({len(report.records)} bisections, "
      f"{report.total_iterations} Lanczos restarts)")

# 3. Quality: the paper's metrics (§8).
pm = partition_metrics(dual_graph(mesh), parts, 16)
print(f"imbalance        : {pm.imbalance} elements (paper bound: ≤1)")
print(f"max / avg nbrs   : {pm.max_neighbors} / {pm.avg_neighbors:.1f}")
print(f"edge cut (ω)     : {pm.edge_cut:.0f}")
print(f"avg message size : {pm.avg_message_size:.0f} words")
ct = comm_time_model(pm)
print(f"comm regime      : {ct['dominated_by']}-dominated "
      f"(m2 = {ct['m2_words']:.0f} words)")
